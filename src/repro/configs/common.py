"""Shape grid shared by all LM archs + input-spec builders.

The four assigned input shapes (seq_len x global_batch):

    train_4k     4,096 x 256    training       -> train_step
    prefill_32k  32,768 x 32    inference      -> prefill
    decode_32k   32,768 x 128   inference      -> decode_step (1 new token)
    long_500k    524,288 x 1    long-context   -> decode_step (sub-quadratic
                                                  archs only; see DESIGN.md)

``input_specs`` returns ShapeDtypeStructs only — the dry-run never
allocates.  Extras (audio frames / vision patches) come from the bundle's
``extra_inputs`` declaration (modality frontends are stubs per the brief).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def positions_struct(cfg, b: int, s: int) -> jax.ShapeDtypeStruct:
    if getattr(cfg, "mrope_section", None):
        return jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def batch_structs(bundle, shape: ShapeSpec) -> dict[str, Any]:
    """Model inputs for one shape as ShapeDtypeStructs.

    train:   {tokens, labels, positions, *extras}
    prefill: {tokens, positions, lengths, *extras}
    decode:  {tokens (B,1), positions (B,1[,3]), lengths}
    """
    cfg = bundle.cfg
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "positions": positions_struct(cfg, b, s),
        }
    elif shape.kind == "prefill":
        s = shape.seq_len
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "positions": positions_struct(cfg, b, s),
            "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
    else:  # decode: one new token against an S_kv cache
        out = {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "positions": positions_struct(cfg, b, 1),
            "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
    if shape.kind != "decode":
        for name, (shape_fn, dtype, _axes) in bundle.extra_inputs.items():
            out[name] = jax.ShapeDtypeStruct(shape_fn(b, shape.seq_len), dtype)
    return out


def batch_axes(bundle, shape: ShapeSpec) -> dict[str, tuple]:
    """Logical axes for each batch input (resolved by dist/sharding.py)."""
    cfg = bundle.cfg
    pos = ("batch", "seq", None) if getattr(cfg, "mrope_section", None) \
        else ("batch", "seq")
    if shape.kind == "train":
        out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
               "positions": pos}
    elif shape.kind == "prefill":
        out = {"tokens": ("batch", "seq"), "positions": pos,
               "lengths": ("batch",)}
    else:
        pos1 = ("batch", None, None) if getattr(cfg, "mrope_section", None) \
            else ("batch", None)
        out = {"tokens": ("batch", None), "positions": pos1,
               "lengths": ("batch",)}
    if shape.kind != "decode":
        for name, (_fn, _dt, axes) in bundle.extra_inputs.items():
            out[name] = axes
    return out


def cache_structs(bundle, shape: ShapeSpec):
    """Decode/prefill caches as ShapeDtypeStructs (no allocation)."""
    return jax.eval_shape(
        lambda: bundle.init_cache(shape.global_batch, shape.seq_len))
