"""whisper-tiny — enc-dec audio backbone (conv frontend stubbed).

[arXiv:2212.04356; unverified] 4L (enc+dec) d_model=384 6H (kv=6) d_ff=1536
vocab=51865.  MHA (kv = heads), LayerNorm, GeLU, learned decoder positions,
sinusoidal encoder positions; encoder sees 1500 precomputed frame embeddings
(the conv1d x2 + GELU frontend is a STUB per the brief).
"""
from repro.models.encdec import EncDecConfig

ARCH_ID = "whisper-tiny"
FAMILY = "audio"
LONG_500K = False           # full attention enc-dec: quadratic — skipped
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> EncDecConfig:
    base = dict(
        name=ARCH_ID,
        encoder_layers=4,
        decoder_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        num_frames=1500,
        act="gelu",
        norm="layernorm",
        max_position=1 << 16,
    )
    base.update(overrides)
    return EncDecConfig(**base)


def reduced_config() -> EncDecConfig:
    return config(encoder_layers=2, decoder_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                  vocab_size=512, num_frames=16, max_position=4096,
                  dense_attn_threshold=4096)
