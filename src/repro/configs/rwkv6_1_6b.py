"""rwkv6-1.6b "Finch" — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified] 24L d_model=2048 d_ff=7168 vocab=65536,
head size 64 (32 WKV heads), token-shift ddlerp + decay LoRA, squared-ReLU
channel mix.  Constant-size state: runs long_500k.
"""
from repro.models.transformer import LMConfig

ARCH_ID = "rwkv6-1.6b"
FAMILY = "ssm"
LONG_500K = True
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=24,
        d_model=2048,
        num_heads=32,             # d_model / rwkv_head_dim
        num_kv_heads=32,
        head_dim=64,
        rwkv_head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        block_pattern=("rwkv",),
        ffn_kind="rwkv_channel",
        norm="layernorm",
        pos_embedding="none",
        tie_embeddings=True,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                  head_dim=16, rwkv_head_dim=16, d_ff=128, vocab_size=512,
                  scan_layers=False, rwkv_chunk=8)
