"""starcoder2-3b — dense GQA kv=2, LayerNorm + plain GeLU MLP with biases.

[arXiv:2402.19173; hf] 30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152,
head_dim=128, RoPE (theta 1e5), tied embeddings, biases everywhere.
"""
from repro.models.transformer import LMConfig

ARCH_ID = "starcoder2-3b"
FAMILY = "dense"
LONG_500K = False
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab_size=49152,
        ffn_kind="plain",
        act="gelu",
        norm="layernorm",
        qkv_bias=True,
        out_bias=True,
        mlp_bias=True,
        rope_theta=1e5,
        tie_embeddings=True,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=128, vocab_size=512, scan_layers=False)
