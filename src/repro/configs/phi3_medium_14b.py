"""phi3-medium-14b — dense GQA, RoPE + SwiGLU + RMSNorm.

[arXiv:2404.14219; unverified] 40L d_model=5120 40H (kv=10) d_ff=17920
vocab=100352, head_dim=128, RoPE 1e4.
"""
from repro.models.transformer import LMConfig

ARCH_ID = "phi3-medium-14b"
FAMILY = "dense"
LONG_500K = False
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        head_dim=128,
        d_ff=17920,
        vocab_size=100352,
        rope_theta=1e4,
        tie_embeddings=False,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=160, vocab_size=512, scan_layers=False)
