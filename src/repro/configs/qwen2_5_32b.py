"""qwen2.5-32b — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf] 64L d_model=5120 40H (kv=8) d_ff=27648
vocab=152064, head_dim=128, RoPE 1e6, untied embeddings.
"""
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2.5-32b"
FAMILY = "dense"
LONG_500K = False
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=False,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=160, vocab_size=512, scan_layers=False)
