"""qwen3-moe-30b-a3b — 128-expert top-8 MoE.

[hf:Qwen/Qwen3-30B-A3B; hf] 48L d_model=2048 32H (kv=4) moe_d_ff=768
vocab=151936, 128 experts top-8, qk_norm, head_dim=128, RoPE 1e6.
"""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "qwen3-moe-30b-a3b"
FAMILY = "moe"
LONG_500K = False
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        moe_d_ff=768,
        ffn_kind="moe",
        moe=MoEConfig(num_experts=128, top_k=8, capacity_factor=1.25,
                      group_tokens=512),
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=False,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=96, moe_d_ff=96, vocab_size=512,
                  moe=MoEConfig(num_experts=8, top_k=2, group_tokens=32,
                                capacity_factor=8.0),
                  scan_layers=False, max_position=4096)
