"""llama4-scout-17b-a16e — 16-expert top-1 MoE with shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H (kv=8)
moe_d_ff=8192 vocab=202048, 16 experts top-1 + llama4 shared expert
(early-fusion multimodality is out of backbone scope per the brief).
"""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "llama4-scout-17b-a16e"
FAMILY = "moe"
LONG_500K = False
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        moe_d_ff=8192,
        shared_expert_ff=8192,
        ffn_kind="moe",
        moe=MoEConfig(num_experts=16, top_k=1, capacity_factor=1.25,
                      group_tokens=512),
        vocab_size=202048,
        rope_theta=5e5,
        tie_embeddings=False,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=128, moe_d_ff=128, shared_expert_ff=128,
                  vocab_size=512,
                  moe=MoEConfig(num_experts=4, top_k=1, group_tokens=32,
                                capacity_factor=8.0),
                  scan_layers=False)
