"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (kv=1, MQA) d_ff=7680
vocab=256000, head_dim=256, pattern (rglru, rglru, local_attn), window 2048,
lru_width 2560, GeGLU MLP.  Sub-quadratic: runs long_500k.
"""
from repro.models.transformer import LMConfig

ARCH_ID = "recurrentgemma-2b"
FAMILY = "hybrid"
LONG_500K = True
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "local_attn"),
        window=2048,
        lru_width=2560,
        act="gelu_tanh",
        rope_theta=1e4,
        tie_embeddings=True,
        scan_layers=False,        # heterogeneous pattern: unrolled
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
                  head_dim=16, d_ff=128, lru_width=64, vocab_size=512,
                  window=8)
