"""qwen3-32b — dense GQA with qk_norm.

[hf:Qwen/Qwen3-8B; hf] 64L d_model=5120 64H (kv=8) d_ff=25600 vocab=151936,
head_dim=128 (q width 8192 != d_model — per-head projections handle it),
qk-RMSNorm, RoPE 1e6, untied.
"""
from repro.models.transformer import LMConfig

ARCH_ID = "qwen3-32b"
FAMILY = "dense"
LONG_500K = False
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=64,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=False,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=160, vocab_size=512, scan_layers=False)
