"""qwen2-vl-72b — VLM backbone with M-RoPE (vision frontend stubbed).

[arXiv:2409.12191; hf] 80L d_model=8192 64H (kv=8) d_ff=29568 vocab=152064,
head_dim=128, QKV bias, M-RoPE sections (16, 24, 24); the first
``num_patch_tokens`` positions carry precomputed patch embeddings
(dynamic-resolution ViT frontend is a STUB per the brief).
"""
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2-vl-72b"
FAMILY = "vlm"
LONG_500K = False
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def config(**overrides) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        mrope_section=(16, 24, 24),
        rope_theta=1e6,
        num_patch_tokens=256,
        tie_embeddings=False,
        scan_layers=True,
    )
    base.update(overrides)
    return LMConfig(**base)


def reduced_config() -> LMConfig:
    return config(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=160, vocab_size=512, num_patch_tokens=4,
                  mrope_section=(2, 3, 3), scan_layers=False)
