"""Architecture registry: the 10 assigned archs + the paper's own workload.

``get_config(arch_id)`` -> model config (exact published numbers);
``get_reduced(arch_id)`` -> CPU-smoke-sized config of the same family;
``arch_cells()`` -> every (arch x shape) dry-run cell with skip notes.
"""

from __future__ import annotations

import importlib

from .common import SHAPES, ShapeSpec, batch_axes, batch_structs, cache_structs  # noqa: F401

_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-32b": "qwen3_32b",
    "starcoder2-3b": "starcoder2_3b",
    "phi3-medium-14b": "phi3_medium_14b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str, **overrides):
    return _module(arch_id).config(**overrides)


def get_reduced(arch_id: str):
    return _module(arch_id).reduced_config()


def arch_shapes(arch_id: str) -> tuple[str, ...]:
    return _module(arch_id).SHAPES


def arch_family(arch_id: str) -> str:
    return _module(arch_id).FAMILY


def arch_cells():
    """All (arch, shape, runnable, note) dry-run cells — 40 total."""
    cells = []
    for arch in ARCH_IDS:
        mod = _module(arch)
        for shape in SHAPES:
            if shape in mod.SHAPES:
                cells.append((arch, shape, True, ""))
            else:
                cells.append((arch, shape, False,
                              "long_500k skipped: full quadratic attention "
                              "(see DESIGN.md §Arch-applicability)"))
    return cells
